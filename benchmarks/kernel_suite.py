"""Kernel microbenchmark suite: each Pallas clustering kernel vs its
pure-jnp reference op at matched shapes (ISSUE 5 satellite).

For every kernel — ``sparse_sim``, ``esicp_gather``, ``segment_update``,
``rho_gather`` — three rows:

    kernel_suite/<name>_reference        the jnp oracle (kernels/ref.py)
    kernel_suite/<name>_pallas           the wrapper, inline occupancy
    kernel_suite/<name>_pallas_planned   the wrapper fed a prepared
                                         KernelPlan (cached head slabs +
                                         precomputed occupancy)

Pallas rows carry ``speedup`` (= reference best / pallas best) so the
machine-readable ``BENCH_kernels.json`` tracks per-kernel ratios across
PRs, plus the platform/interpret execution metadata from
``benchmarks.common.exec_meta`` — off-TPU the kernels run in interpret
mode, where the ratio measures the correctness path, not TPU performance
(the ``interpret`` flag says exactly that).

Shapes follow the reduced-PubMed regime (Zipf-skewed synthetic corpus →
realistic occupancy); ``REPRO_BENCH_SMOKE=1`` shrinks them for CI.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_row, time_call_warm
from repro.kernels import ops, ref
from repro.kernels.plan import prepare_plan


def _shapes():
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return dict(b=256, p=32, d=1024, k=128, repeat=2)
    return dict(b=512, p=64, d=2048, k=256, repeat=3)


def _corpus(b: int, p: int, d: int, k: int, seed: int = 0):
    """Zipf-skewed synthetic tuples in df-rank order: high-df terms at the
    HIGH ids (ascending-df layout), so the occupancy/head machinery sees
    the skew it was built for."""
    rng = np.random.default_rng(seed)
    # Zipf ranks over [1, d]; rank 1 = most frequent → highest df-rank id.
    ranks = np.minimum(rng.zipf(1.3, size=(b, p)), d)
    ids = np.sort((d - ranks).astype(np.int32), axis=1)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(p // 2, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
    means_t = np.where(rng.random((d, k)) < 0.15,
                       rng.random((d, k)), 0.0).astype(np.float32)
    assign = rng.integers(0, k, b).astype(np.int32)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign))


def _timed(fn, repeat):
    def call():
        return jax.block_until_ready(fn())

    return time_call_warm(call, repeat=repeat)


def run():
    cfg = _shapes()
    b, p, d, k, repeat = cfg["b"], cfg["p"], cfg["d"], cfg["k"], cfg["repeat"]
    ids, vals, means_t, assign = _corpus(b, p, d, k)
    t_th = jnp.asarray(int(0.8 * d), jnp.int32)
    v_th = jnp.asarray(0.1, jnp.float32)
    plan = prepare_plan(ids, vals, dim=d)
    shape_meta = {"B": b, "P": p, "D": d, "K": k}

    cases = {
        "sparse_sim": (
            lambda: ref.sparse_sim(ids, vals, means_t),
            lambda: ops.sparse_sim(ids, vals, means_t),
            lambda: ops.sparse_sim(ids, vals, means_t, plan=plan),
        ),
        "esicp_gather": (
            lambda: ref.esicp_gather(ids, vals, means_t, t_th, v_th),
            lambda: ops.esicp_gather(ids, vals, means_t, t_th, v_th),
            lambda: ops.esicp_gather(ids, vals, means_t, t_th, v_th,
                                     plan=plan),
        ),
        "segment_update": (
            lambda: ref.segment_update(assign, ids, vals, k, d),
            lambda: ops.segment_update(assign, ids, vals, k=k, d=d),
            lambda: ops.segment_update(assign, ids, vals, k=k, d=d,
                                       plan=plan),
        ),
        "rho_gather": (
            lambda: ref.rho_gather(assign, ids, vals, means_t),
            lambda: ops.rho_gather(assign, ids, vals, means_t),
            lambda: ops.rho_gather(assign, ids, vals, means_t, plan=plan),
        ),
    }

    rows = []
    for name, (ref_fn, pal_fn, planned_fn) in cases.items():
        _, ref_best, ref_warm = _timed(jax.jit(ref_fn), repeat)
        rows.append(bench_row(f"kernel_suite/{name}_reference",
                              ref_best * 1e6, "reference",
                              warmup_us=ref_warm * 1e6, **shape_meta))
        for suffix, fn in (("pallas", pal_fn), ("pallas_planned",
                                                planned_fn)):
            _, best, warm = _timed(fn, repeat)
            rows.append(bench_row(f"kernel_suite/{name}_{suffix}",
                                  best * 1e6, "pallas", warmup_us=warm * 1e6,
                                  speedup=round(ref_best / best, 4),
                                  **shape_meta))
    return rows
