"""Kernel microbenchmark suite: each Pallas clustering kernel vs its
pure-jnp reference op at matched shapes, tuned vs default vs reference
(ISSUE 5 satellite; compiled-mode + autotuner rows from ISSUE 6).

For every kernel — ``sparse_sim``, ``esicp_gather``, ``segment_update``,
``rho_gather`` — four rows:

    kernel_suite/<name>_reference        the jnp oracle (kernels/ref.py)
    kernel_suite/<name>_pallas           the wrapper, inline occupancy
    kernel_suite/<name>_pallas_planned   the wrapper fed a prepared
                                         KernelPlan (cached head slabs +
                                         precomputed occupancy)
    kernel_suite/<name>_pallas_tuned     the wrapper under the autotuner's
                                         winning TunedConfig + matching plan

plus one ``kernel_suite/autotuner`` meta-row recording what the
roofline-pruned search did (candidates, pruned fraction, winner).

Execution-mode honesty: the suite *attempts* compiled (non-interpret)
Pallas first and falls back to interpret mode only when the platform
refuses to lower it (CPU backends).  Every pallas row carries the live
``interpret``/``mode`` flags, and cross-mode ratios are suppressed:
``speedup`` (vs the compiled-XLA reference) is null with
``comparable: false`` whenever the kernels ran interpreted.  The
``speedup_vs_default`` field on tuned rows compares two same-mode pallas
timings and is therefore always valid.

Shapes follow the reduced-PubMed regime (Zipf-skewed synthetic corpus →
realistic occupancy); ``REPRO_BENCH_SMOKE=1`` shrinks the shapes AND the
autotuner budget (repro.tune.SearchBudget.default) for CI.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_row, speedup_fields, time_call_warm
from repro.kernels import ops, ref
from repro.kernels.plan import prepare_plan
from repro.tune import DEFAULT_TUNED
from repro.tune.search import SearchBudget, search_tuned_config


def _shapes():
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return dict(b=256, p=32, d=1024, k=128, repeat=2)
    return dict(b=512, p=64, d=2048, k=256, repeat=3)


def _corpus(b: int, p: int, d: int, k: int, seed: int = 0):
    """Zipf-skewed synthetic tuples in df-rank order: high-df terms at the
    HIGH ids (ascending-df layout), so the occupancy/head machinery sees
    the skew it was built for."""
    rng = np.random.default_rng(seed)
    # Zipf ranks over [1, d]; rank 1 = most frequent → highest df-rank id.
    ranks = np.minimum(rng.zipf(1.3, size=(b, p)), d)
    ids = np.sort((d - ranks).astype(np.int32), axis=1)
    vals = rng.random((b, p)).astype(np.float32)
    nnz = rng.integers(p // 2, p + 1, b)
    for i in range(b):
        vals[i, nnz[i]:] = 0.0
    means_t = np.where(rng.random((d, k)) < 0.15,
                       rng.random((d, k)), 0.0).astype(np.float32)
    assign = rng.integers(0, k, b).astype(np.int32)
    return (jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(means_t),
            jnp.asarray(assign))


def _timed(fn, repeat):
    def call():
        return jax.block_until_ready(fn())

    return time_call_warm(call, repeat=repeat)


def _probe_compiled(ids, vals, means_t) -> bool:
    """Attempt one compiled (non-interpret) kernel launch.

    True → the platform lowers Pallas natively (TPU) and the whole suite
    times compiled kernels; False → only the interpreter is available and
    every pallas row says so (``mode: interpret``, ``comparable: false``)
    instead of dressing interpreter dispatch up as kernel time.
    """
    try:
        jax.block_until_ready(
            ops.sparse_sim(ids[:8], vals[:8], means_t, interpret=False))
        return True
    except Exception:
        return False


def run():
    cfg = _shapes()
    b, p, d, k, repeat = cfg["b"], cfg["p"], cfg["d"], cfg["k"], cfg["repeat"]
    ids, vals, means_t, assign = _corpus(b, p, d, k)
    t_th = jnp.asarray(int(0.8 * d), jnp.int32)
    v_th = jnp.asarray(0.1, jnp.float32)
    shape_meta = {"B": b, "P": p, "D": d, "K": k}

    compiled = _probe_compiled(ids, vals, means_t)
    interpret = not compiled
    mode = "compiled" if compiled else "interpret"

    # Roofline-pruned autotune at the suite's own regime (budget shrinks
    # under REPRO_BENCH_SMOKE with the shapes).
    budget = SearchBudget.default()
    t0 = time.perf_counter()
    tuned, stats = search_tuned_config(ids, vals, dim=d, k=k, budget=budget)
    search_s = time.perf_counter() - t0

    plan = prepare_plan(ids, vals, dim=d)                 # default geometry
    tplan = prepare_plan(ids, vals, dim=d, tuned=tuned)   # winner geometry

    def variants(ref_fn, pal):
        return (
            ("reference", ref_fn, None),
            ("pallas", lambda: pal(plan=None, tuned=None), False),
            ("pallas_planned", lambda: pal(plan=plan, tuned=None), False),
            ("pallas_tuned", lambda: pal(plan=tplan, tuned=tuned), True),
        )

    cases = {
        "sparse_sim": variants(
            lambda: ref.sparse_sim(ids, vals, means_t),
            lambda **kw: ops.sparse_sim(ids, vals, means_t,
                                        interpret=interpret, **kw)),
        "esicp_gather": variants(
            lambda: ref.esicp_gather(ids, vals, means_t, t_th, v_th),
            lambda **kw: ops.esicp_gather(ids, vals, means_t, t_th, v_th,
                                          interpret=interpret, **kw)),
        "segment_update": variants(
            lambda: ref.segment_update(assign, ids, vals, k, d),
            lambda **kw: ops.segment_update(assign, ids, vals, k=k, d=d,
                                            interpret=interpret, **kw)),
        "rho_gather": variants(
            lambda: ref.rho_gather(assign, ids, vals, means_t),
            lambda **kw: ops.rho_gather(assign, ids, vals, means_t,
                                        interpret=interpret, **kw)),
    }

    rows = []
    for name, var in cases.items():
        ref_best = default_best = None
        for suffix, fn, is_tuned in var:
            if suffix == "reference":
                _, ref_best, warm = _timed(jax.jit(fn), repeat)
                rows.append(bench_row(f"kernel_suite/{name}_reference",
                                      ref_best * 1e6, "reference",
                                      warmup_us=warm * 1e6, **shape_meta))
                continue
            _, best, warm = _timed(fn, repeat)
            extra = dict(shape_meta)
            extra.update(interpret=interpret, mode=mode, tuned=is_tuned)
            # Cross-engine speedup (vs the compiled-XLA reference) is only a
            # kernel measurement when the kernels actually compiled.
            extra.update(speedup_fields(ref_best, best, comparable=compiled))
            if suffix == "pallas_planned":
                default_best = best
            if is_tuned and default_best is not None:
                # Same engine, same mode, tuned vs default geometry — valid
                # on every platform, including interpret-only ones.
                extra["speedup_vs_default"] = round(default_best / best, 4)
            rows.append(bench_row(f"kernel_suite/{name}_{suffix}",
                                  best * 1e6, "pallas", warmup_us=warm * 1e6,
                                  **extra))

    rows.append(bench_row(
        "kernel_suite/autotuner", search_s * 1e6, "pallas",
        interpret=interpret, mode=mode, tuned=True,
        comparable=False, speedup=None,
        winner=tuned.to_dict(), **stats.to_dict(), **shape_meta))
    return rows
