"""Paper Figs. 2–4(a) — universal characteristics of the corpus + mean set:
Zipf on tf/df, bounded Zipf on mf, df–mf correlation, feature concentration.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import corpus, csv_row, make_estimator
from repro.core import metrics


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    tf = np.zeros(docs.dim)
    np.add.at(tf, np.asarray(docs.ids).ravel(), np.asarray(docs.vals).ravel() > 0)

    alpha_df = metrics.zipf_fit(np.asarray(df))
    res = make_estimator(k=job.k, algo="esicp", max_iter=6,
                          batch_size=4096, seed=0).fit(docs, df=df)
    means_t = res.state_.index.means_t
    mf = np.asarray(jnp.sum(means_t > 0, axis=1))
    alpha_mf = metrics.zipf_fit(mf)
    skew = metrics.mean_value_skew(means_t)
    corr = np.corrcoef(np.log1p(np.asarray(df)), np.log1p(mf))[0, 1]

    return [
        csv_row("fig2/zipf_alpha_df", 0, f"alpha={alpha_df:.3f}"),
        csv_row("fig2/bounded_zipf_alpha_mf", 0, f"alpha={alpha_mf:.3f};max_mf<=K={mf.max() <= job.k}"),
        csv_row("fig3/df_mf_log_corr", 0, f"corr={corr:.3f}"),
        csv_row("fig4a/concentration", 0,
                f"frac_dominant={skew['frac_dominant']:.3f};top1_mass={skew['top1_mass_mean']:.3f}"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
