"""Paper App. H — initial-state independence: NMI and objective CV across
random seeds, increasing with K.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, csv_row, make_estimator
from repro.core import metrics


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    sub = docs.slice_rows(0, 6000)
    rows = []
    for k in (10, 50, 150):
        assigns, objs = [], []
        for seed in range(4):
            r = make_estimator(k=k, algo="esicp", max_iter=15,
                                batch_size=3000, seed=seed).fit(sub, df=df)
            assigns.append(r.labels_)
            objs.append(r.objective_)
        nmi_mean, nmi_std = metrics.pairwise_nmi(assigns)
        cv = metrics.coefficient_of_variation(objs)
        rows.append(csv_row(f"apph/k{k}", 0,
                            f"nmi={nmi_mean:.3f}±{nmi_std:.3f};obj_cv={cv:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
