"""Compounded-pruning suite (ISSUE 7; DESIGN.md §11): per-iteration Mult +
wall-time for every algo mode, machine-readable as ``BENCH_pruning.json``.

One fit per mode on a shared well-separated corpus (the regime where bound
maintenance legitimately pays from iteration 2: topics sharp enough that
ρ_self rises quickly, documents long enough that a skipped row scan is worth
real work).  Every mode is exact — bit-identical assignments to ``mivi`` per
backend (asserted here, not assumed) — so the rows compare *pruning
economics only*:

  ``pruning/<mode>/iter<r>``  — per-iteration rows: ``mult`` (the paper's
      multiply-add count a CPU implementation of that mode would execute),
      ``cpr``, and ``us_per_call`` = that iteration's own ``elapsed_s``
      from the fit history.  The eager estimation prologue (iterations in
      ``est_iters``) is timed individually (``wall: "measured"``); the
      fused ``while_loop`` remainder runs all its iterations in one device
      call, so those rows carry the fused segment's mean and say so
      (``wall: "fused_mean"``) — a per-iteration number is never fabricated
      from the whole fit's wall clock.
  ``pruning/<mode>/fit``      — one per mode: total steady-state fit wall
      time, iterations, total Mult, and a wall-clock ``speedup`` vs the
      matched ``mivi`` fit (same backend, same execution mode — the only
      comparison ``benchmarks.ratchet`` accepts).  The ``mivi`` row IS the
      reference, so it carries no self-referential ``vs``/``speedup``.

The ratchet invariants (enforced by ``benchmarks/ratchet.py`` on this
file's JSON): ``bounds``/``sketch`` rows report Mult <= the matched
``mivi`` row at every iteration, and the compounded ``bounds-esicp`` row is
*strictly* below every single-technique row on iterations >= 2.

``REPRO_BENCH_SMOKE=1`` keeps the corpus (the invariants are corpus
statements, not scale statements) and trims the iteration budget.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import (bench_row, default_backend, make_estimator,
                               speedup_fields)
from repro.data import make_corpus
from repro.data.synthetic import CorpusSpec

# Single-technique modes the compounded mode must strictly beat on
# iterations >= 2, plus the exhaustive baseline they are all measured
# against.  Order fixes the row order in the JSON artifact.
MODES = ("mivi", "icp", "es", "esicp", "bounds", "sketch", "bounds-esicp")
COMBINED = "bounds-esicp"

# Well-separated long-document regime (DESIGN.md §11): nt ~ 300 makes a
# skipped row scan worth ~K·nt multiply-adds, sharp topics make ρ_self
# beat the drift-loosened group bounds from iteration 2 on.
SPEC = CorpusSpec(n_docs=6000, vocab=8192, nt_mean=300.0, n_topics=96,
                  topic_sharpness=2000.0, seed=3)
K = 64
MAX_ITER = 8
SEED = 0


def _fit(docs, df, mode, backend, max_iter):
    est = make_estimator(K, algo=mode, backend=backend, max_iter=max_iter,
                         batch_size=2048, seed=SEED)
    t0 = time.perf_counter()
    est.fit(docs, df=df)
    return est, time.perf_counter() - t0


def run():
    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    max_iter = 4 if smoke else MAX_ITER
    backend = default_backend()
    docs, df, _, _ = make_corpus(SPEC)

    fits = {}
    rows = []
    for mode in MODES:
        # Warm fit compiles (per-mode traces); the second fit is the timed,
        # steady-state one — the time_call_warm discipline at fit scope.
        _fit(docs, df, mode, backend, max_iter)
        fits[mode] = _fit(docs, df, mode, backend, max_iter)

    ref, ref_wall = fits["mivi"]
    ref_iter_s = ref_wall / max(len(ref.history_), 1)
    for mode in MODES:
        est, wall = fits[mode]
        assert np.array_equal(est.labels_, ref.labels_), (
            f"exactness violated: {mode} diverged from mivi")
        n_iter = len(est.history_)
        per_iter_s = wall / max(n_iter, 1)
        for h in est.history_:
            # elapsed_s is the iteration's OWN wall: exact for the eagerly
            # timed estimation prologue, the fused segment's mean for the
            # while_loop remainder — labelled so neither can be misread.
            measured = h["iteration"] in est.est_iters
            rows.append(bench_row(
                f"pruning/{mode}/iter{h['iteration']}",
                float(h["elapsed_s"]) * 1e6,
                backend, algo=mode, iteration=h["iteration"],
                mult=float(h["mult"]), cpr=float(h["cpr"]),
                wall="measured" if measured else "fused_mean"))
        fit_row = bench_row(
            f"pruning/{mode}/fit", per_iter_s * 1e6,
            backend, algo=mode, n_iter=n_iter, total_s=round(wall, 4),
            mult_total=float(sum(h["mult"] for h in est.history_)))
        if mode != "mivi":   # the reference row gets no self-speedup of 1.0
            fit_row.update(vs="pruning/mivi/fit",
                           **speedup_fields(ref_iter_s, per_iter_s,
                                            comparable=True))
        rows.append(fit_row)
    return rows
