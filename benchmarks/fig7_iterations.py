"""Paper Figs. 7–8 — Mult and CPR along iterations until convergence.

The paper's signature curve: ES-ICP's Mult/CPR drop from the *first*
iterations (the ES filter works early), while ICP-only catches up late as
centroids freeze.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, csv_row, make_estimator


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    rows = []
    for algo in ["mivi", "icp", "esicp"]:
        r = make_estimator(k=job.k, algo=algo, max_iter=12,
                            batch_size=4096, seed=0).fit(docs, df=df)
        mult = [h["mult"] for h in r.history_]
        cpr = [h["cpr"] for h in r.history_]
        early = float(np.mean(mult[1:4]))
        late = float(np.mean(mult[-3:]))
        rows.append(csv_row(
            f"fig7/{algo}", 0,
            f"mult_it2={mult[1]:.3g};mult_early={early:.3g};mult_late={late:.3g};"
            f"cpr_it2={cpr[1]:.4g};cpr_last={cpr[-1]:.4g}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
