"""Paper Table II / App. E — loop orientation: MIVI vs DIVI (vs Ding+).

The paper's point: identical multiplication counts, wildly different wall
time, because DIVI's loop order (outer loop over *means*, inner over long
object-postings) destroys locality.  The TPU analogue measured here: the
mean-inverted TAAT orientation streams (B, K) accumulator tiles, while the
object-inverted orientation streams (K, N) tiles whose gather strides are
data-sized, not mean-sized.  Ding+ (triangle-inequality, per-object bound
state ∝ K) is represented analytically: its Mult reduction (paper: 0.23×)
cannot pay for its branch/locality damage — we report its Mult model only,
since branch mispredictions have no TPU analogue (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import default_backend, corpus, time_call, csv_row
from repro.core import init_state, StructuralParams
from repro.core.assignment import assignment_step


def _divi_sims(docs, means_t):
    """DIVI orientation: object-inverted index — outer over means."""
    from repro.sparse import to_dense
    x_dense_t = to_dense(docs).T                   # (D, N) 'object index'

    def per_mean(mcol):
        return mcol @ x_dense_t                    # (N,) one mean at a time

    return jax.lax.map(per_mean, means_t.T)        # (K, N)


def run():
    job, docs, df, perm, topics = corpus("pubmed")
    sub = docs.slice_rows(0, 4096)
    k = 128
    state = init_state(sub, k, StructuralParams.trivial(sub.dim), seed=0)
    means_t = state.index.means_t

    mivi = jax.jit(lambda: assignment_step(
        "mivi", sub, state.index, state.assign, state.rho_self,
        jnp.zeros_like(state.assign, bool),
        backend=default_backend()).rho.sum())
    divi = jax.jit(lambda: _divi_sims(sub, means_t).sum())

    _, t_mivi = time_call(lambda: mivi().block_until_ready())
    _, t_divi = time_call(lambda: divi().block_until_ready())

    res = assignment_step("mivi", sub, state.index, state.assign,
                          state.rho_self, jnp.zeros_like(state.assign, bool),
                          backend=default_backend())
    mult = float(res.mult)
    # Ding+ model (paper Table II): 0.2284x Mult, ~3x time via BM/LLCM
    rows = [
        csv_row("table2/mivi", t_mivi * 1e6, f"mult={mult:.3g}"),
        csv_row("table2/divi", t_divi * 1e6,
                f"mult={mult:.3g};time_ratio={t_divi / t_mivi:.2f}"),
        csv_row("table2/ding+_model", 0.0,
                f"mult={0.2284 * mult:.3g};paper_time_ratio=2.89"),
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
